"""Pallas kernels vs pure-jnp oracles (interpret mode; shape/dtype sweeps)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sparse_vec as sv
from repro.core.sparse_vec import SparseChunk
from repro.kernels import ops
from repro.kernels.onehot_scatter import onehot_scatter_add
from repro.kernels.rank_merge import rank_counts
from repro.kernels.ref import (onehot_scatter_add_ref, rank_counts_ref,
                               spmv_ell_ref)
from repro.kernels.spmv_ell import spmv_ell


# ---------------------------------------------------------------------------
# onehot_scatter_add
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,w,rows", [(16, 1, 8), (100, 7, 50), (512, 128, 256),
                                      (513, 130, 100), (64, 1, 1)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_onehot_scatter_sweep(c, w, rows, dtype):
    rng = np.random.RandomState(c + w)
    pos = rng.randint(-1, rows + 2, c).astype(np.int32)   # incl. out-of-range
    val = rng.randn(c, w).astype(dtype)
    got = onehot_scatter_add(jnp.asarray(pos), jnp.asarray(val), rows)
    ref = onehot_scatter_add_ref(jnp.asarray(pos), jnp.asarray(val), rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-3 if dtype == np.float16 else 1e-5,
                               atol=1e-3 if dtype == np.float16 else 1e-5)


@pytest.mark.parametrize("bm,bk,bn", [(8, 16, 8), (128, 512, 128)])
def test_onehot_scatter_blockspec_sweep(bm, bk, bn):
    rng = np.random.RandomState(0)
    pos = rng.randint(0, 40, 200).astype(np.int32)
    val = rng.randn(200, 20).astype(np.float32)
    got = onehot_scatter_add(jnp.asarray(pos), jnp.asarray(val), 40,
                             bm=bm, bk=bk, bn=bn)
    ref = onehot_scatter_add_ref(jnp.asarray(pos), jnp.asarray(val), 40)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# rank_counts (merge ranks)
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(0, 300), min_size=1, max_size=150),
       st.lists(st.integers(0, 300), min_size=1, max_size=150),
       st.booleans())
@settings(max_examples=40, deadline=None)
def test_rank_counts_property(a, b, strict):
    a = np.sort(np.array(a, np.uint32))
    b = np.sort(np.array(b, np.uint32))
    got = rank_counts(jnp.asarray(a), jnp.asarray(b), strict=strict)
    ref = rank_counts_ref(jnp.asarray(a), jnp.asarray(b),
                          "left" if strict else "right")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_rank_counts_with_sentinels():
    a = np.array([5, 10, 0xFFFFFFFF, 0xFFFFFFFF], np.uint32)
    b = np.array([1, 10, 0xFFFFFFFF], np.uint32)
    got_l = np.asarray(rank_counts(jnp.asarray(a), jnp.asarray(b), strict=True))
    np.testing.assert_array_equal(got_l, [1, 1, 2, 2])
    got_r = np.asarray(rank_counts(jnp.asarray(a), jnp.asarray(b),
                                   strict=False))
    np.testing.assert_array_equal(got_r, [1, 2, 3, 3])


def test_merge_is_permutation():
    rng = np.random.RandomState(1)
    for ca, cb in [(64, 64), (100, 30), (1, 700)]:
        a = np.sort(rng.randint(0, 500, ca).astype(np.uint32))
        b = np.sort(rng.randint(0, 500, cb).astype(np.uint32))
        ra = np.arange(ca) + np.asarray(
            rank_counts(jnp.asarray(a), jnp.asarray(b), strict=True))
        rb = np.arange(cb) + np.asarray(
            rank_counts(jnp.asarray(b), jnp.asarray(a), strict=False))
        assert sorted(list(ra) + list(rb)) == list(range(ca + cb))


# ---------------------------------------------------------------------------
# kernel-backed segment_compact / merge_add vs the pure-jnp versions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,w,out_cap", [(64, 0, 64), (200, 4, 120),
                                         (33, 0, 16)])
def test_segment_compact_kernel_vs_ref(c, w, out_cap):
    rng = np.random.RandomState(c)
    n = rng.randint(1, c + 1)
    idx = np.full(c, 0xFFFFFFFF, np.uint32)
    idx[:n] = np.sort(rng.randint(0, 80, n).astype(np.uint32))
    val = rng.randn(*((c, w) if w else (c,))).astype(np.float32)
    ch = SparseChunk(idx=jnp.asarray(idx), val=jnp.asarray(val))
    ref = sv.segment_compact(ch, out_cap)
    got = ops.segment_compact(ch, out_cap)
    np.testing.assert_array_equal(np.asarray(ref.idx), np.asarray(got.idx))
    np.testing.assert_allclose(np.asarray(ref.val), np.asarray(got.val),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(1, 120), st.integers(1, 120), st.integers(0, 3),
       st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_merge_add_kernel_property(ca, cb, w, seed):
    rng = np.random.RandomState(seed)

    def mk(c):
        n = rng.randint(1, c + 1)
        idx = np.full(c, 0xFFFFFFFF, np.uint32)
        idx[:n] = np.sort(rng.randint(0, 150, n).astype(np.uint32))
        val = rng.randn(*((c, w) if w else (c,))).astype(np.float32)
        mask = idx != 0xFFFFFFFF
        val = val * (mask[:, None] if w else mask)
        return SparseChunk(idx=jnp.asarray(idx), val=jnp.asarray(val))

    a, b = mk(ca), mk(cb)
    ref = sv.merge_add(a, b, 200)
    got = ops.merge_add(a, b, 200)
    np.testing.assert_array_equal(np.asarray(ref.idx), np.asarray(got.idx))
    np.testing.assert_allclose(np.asarray(ref.val), np.asarray(got.val),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# spmv_ell
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,k,n", [(8, 1, 16), (500, 17, 300), (256, 64, 1000),
                                   (1, 5, 10)])
@pytest.mark.parametrize("bm", [32, 256])
def test_spmv_sweep(r, k, n, bm):
    rng = np.random.RandomState(r + k)
    cols = rng.randint(-1, n, (r, k)).astype(np.int32)
    w = rng.randn(r, k).astype(np.float32)
    x = rng.randn(n).astype(np.float32)
    got = spmv_ell(jnp.asarray(cols), jnp.asarray(w), jnp.asarray(x), bm=bm)
    ref = spmv_ell_ref(jnp.asarray(cols), jnp.asarray(w), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)
