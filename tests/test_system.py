"""End-to-end behaviour: training converges; serving is self-consistent;
the public SparseAllreduce API round-trips through both backends."""
import numpy as np
import pytest

from repro.core import SparseAllreduce
from repro.core.simulator import dense_oracle


def test_train_loss_decreases():
    """Deterministic memorization check: repeated batch, loss must drop
    sharply (fresh-stream convergence is exercised by the launcher test)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.optim.adamw import AdamW
    from repro.train.step import make_train_step
    cfg = get_config("qwen1.5-0.5b").reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    step, _ = make_train_step(cfg, mesh, opt=AdamW(lr=1e-3))
    params = T.init_params(cfg, 1, seed=0)
    opt = AdamW().init(params)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (4, 64)), jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab, (4, 64)), jnp.int32)}
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0, losses


def test_train_launcher_runs():
    from repro.launch.train import main as train_main
    loss = train_main(["--arch", "qwen1.5-0.5b", "--reduced",
                       "--steps", "8", "--batch", "4", "--seq", "64"])
    assert np.isfinite(loss) and loss < 8.0


def test_train_sparse_sync_untied():
    from repro.launch.train import main as train_main
    loss = train_main(["--arch", "qwen1.5-0.5b", "--reduced", "--untied",
                       "--sync", "sparse", "--steps", "6", "--batch", "4",
                       "--seq", "64"])
    assert np.isfinite(loss)


def test_serve_generates():
    from repro.launch.serve import main as serve_main
    gen = serve_main(["--arch", "qwen1.5-0.5b", "--reduced",
                      "--requests", "2", "--prompt-len", "16", "--gen", "6"])
    assert gen.shape == (2, 6)
    assert gen.dtype == np.int32


def test_api_device_backend_matches_sim():
    """Same indices/values through backend='sim' and backend='device'
    (device path runs on 1 CPU device with a 1-node mesh fallback? no —
    8 logical nodes need 8 devices; use the sim-vs-device equivalence via
    the planned path on a single-device 1-node instance)."""
    rng = np.random.RandomState(0)
    M, R = 1, 500
    out_idx = [rng.randint(0, R, 40).astype(np.uint32)]
    out_val = [rng.randn(40)]
    in_idx = [rng.choice(R, 30, replace=False).astype(np.uint32)]
    for backend in ("sim", "device"):
        ar = SparseAllreduce(M, (), backend=backend, seed=3)
        ar.config(out_idx, in_idx)
        got = ar.reduce(out_val)
        want = dense_oracle(out_idx, out_val, in_idx, ar.perm)
        np.testing.assert_allclose(got[0], want[0], rtol=1e-5, atol=1e-6)


def test_api_device_reduce_reuses_staging():
    """Repeated same-config reduces must reuse the host staging buffer
    (no per-call np.zeros + per-node copy loop) and stay correct when the
    values change between calls — including with value_width > 1."""
    rng = np.random.RandomState(1)
    M, R, W = 1, 400, 3
    out_idx = [rng.randint(0, R, 50).astype(np.uint32)]
    in_idx = [rng.choice(R, 25, replace=False).astype(np.uint32)]
    ar = SparseAllreduce(M, (), backend="device", seed=5, value_width=W)
    ar.config(out_idx, in_idx)
    assert ar._staging is None                  # built lazily on first call
    for it in range(3):
        out_val = [rng.randn(50, W)]
        got = ar.reduce(out_val)
        want = dense_oracle(out_idx, out_val, in_idx, ar.perm, width=W)
        np.testing.assert_allclose(got[0], want[0], rtol=1e-5, atol=1e-6)
        if it == 0:
            staging = ar._staging
        else:                                   # same buffer, not re-alloc'd
            assert ar._staging is staging
    with pytest.raises(ValueError):             # wrong total length
        ar.reduce([np.zeros((49, W))])


def test_whisper_end_to_end_serve():
    from repro.launch.serve import main as serve_main
    gen = serve_main(["--arch", "whisper-base", "--reduced",
                      "--requests", "2", "--prompt-len", "8", "--gen", "4"])
    assert gen.shape == (2, 4)
