"""Deterministic stand-in for the subset of the `hypothesis` API this
test suite uses (`given`, `settings`, `strategies as st`).

The real hypothesis is the declared test dependency (requirements-dev.txt /
pyproject `[test]` extra) and is preferred whenever importable;
tests/conftest.py only puts this package on sys.path when it is missing, so
hermetic containers can still run the full suite.  Differences from the
real thing: examples are drawn from a fixed-seed RNG (fully deterministic,
lightly boundary-biased), there is no shrinking, and the failing example is
reported in the exception chain instead of being minimised.
"""
from __future__ import annotations

import functools
import inspect

import numpy as np

from . import strategies  # noqa: F401  (re-export: `from hypothesis import strategies`)

_DEFAULT_MAX_EXAMPLES = 20
_SEED = 0x5EED5


def settings(max_examples=None, deadline=None, **_ignored):
    """Decorator recording example-count config on the test function."""

    def deco(fn):
        fn._shim_settings = {"max_examples": max_examples or _DEFAULT_MAX_EXAMPLES}
        return fn

    return deco


def given(*strats, **kw_strats):
    """Run the test once per drawn example (no shrinking)."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_shim_settings", None) or \
                getattr(fn, "_shim_settings", None) or {}
            n = conf.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.RandomState(_SEED)
            for ex in range(n):
                drawn = [s.draw(rng) for s in strats]
                drawn_kw = {k: s.draw(rng) for k, s in kw_strats.items()}
                try:
                    fn(*args, *drawn, **drawn_kw, **kwargs)
                except _UnsatisfiedAssumption:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{ex}: args={drawn!r} "
                        f"kwargs={drawn_kw!r}") from e

        # Hide the original signature from pytest so strategy-filled
        # parameters are not mistaken for fixtures.
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        wrapper.is_hypothesis_test = True
        return wrapper

    return deco


def assume(condition) -> bool:
    """Degraded `assume`: skip-worthy conditions just pass the example."""
    if not condition:
        raise _UnsatisfiedAssumption()
    return True


class _UnsatisfiedAssumption(Exception):
    pass


__all__ = ["given", "settings", "strategies", "assume"]
