"""Strategy combinators for the vendored hypothesis shim.

Each strategy wraps a ``draw(rng) -> value`` callable over a shared
``numpy.random.RandomState``.  Draws are lightly boundary-biased (a few
percent of examples pin integers/floats to their bounds and lists to their
min/max sizes) so the usual edge cases still get exercised without real
hypothesis's adaptive search.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence


class SearchStrategy:
    def __init__(self, draw: Callable[[Any], Any]):
        self.draw = draw

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self.draw(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(1000):
                x = self.draw(rng)
                if pred(x):
                    return x
            raise ValueError("filter predicate rejected 1000 draws")
        return SearchStrategy(draw)


def integers(min_value: int = 0, max_value: int = 2 ** 31 - 1) -> SearchStrategy:
    span = max_value - min_value

    def draw(rng):
        u = rng.random_sample()
        if u < 0.04:
            return min_value
        if u < 0.08:
            return max_value
        # random_sample keeps this exact for spans beyond randint's int range
        return min(min_value + int(rng.random_sample() * (span + 1)), max_value)

    return SearchStrategy(draw)


def floats(min_value: float = 0.0, max_value: float = 1.0,
           allow_nan: bool = False, allow_infinity: bool = False
           ) -> SearchStrategy:
    def draw(rng):
        u = rng.random_sample()
        if u < 0.04:
            return float(min_value)
        if u < 0.08:
            return float(max_value)
        return float(min_value + rng.random_sample() * (max_value - min_value))

    return SearchStrategy(draw)


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.random_sample() < 0.5))


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 32, unique: bool = False) -> SearchStrategy:
    def draw(rng):
        u = rng.random_sample()
        if u < 0.06:
            n = min_size
        elif u < 0.12:
            n = max_size
        else:
            n = int(rng.randint(min_size, max_size + 1))
        out = []
        seen = set()
        attempts = 0
        while len(out) < n and attempts < 100 * (n + 1):
            x = elements.draw(rng)
            attempts += 1
            if unique:
                if x in seen:
                    continue
                seen.add(x)
            out.append(x)
        return out

    return SearchStrategy(draw)


def tuples(*strats: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.draw(rng) for s in strats))


def sampled_from(seq: Sequence) -> SearchStrategy:
    seq = list(seq)
    return SearchStrategy(lambda rng: seq[int(rng.randint(len(seq)))])


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def one_of(*strats: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: strats[int(rng.randint(len(strats)))].draw(rng))
