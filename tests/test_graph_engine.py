"""Device-resident iterative graph engine (repro.graph.engine).

In-process: the vectorized ELL construction vs a per-edge loop oracle,
ell_matvec paths, engine validation, and — on a single-device 1-node
mesh — the amortization contract: ``engine.run(k)`` performs exactly ONE
jitted dispatch and traces the per-round body exactly once, however
large k is.  Subprocess (16 forced host devices): k-iteration
device-vs-sim parity for PageRank / HADI / spectral plus the same
one-dispatch regression on a real multi-device mesh.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data.pipeline import powerlaw_graph
from repro.graph.engine import build_ell, ell_matvec, stack_ell
from repro.graph.pagerank import (build_partitions, make_pagerank_engine,
                                  pagerank, pagerank_dense_reference)

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=16",
            PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src")
            + os.pathsep + os.environ.get("PYTHONPATH", ""))


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# vectorized ELL build (the old per-edge Python loop, kept as oracle here)
# ---------------------------------------------------------------------------

def _ell_loop_reference(rows, cols, weights, n_rows, min_k=1):
    counts = np.bincount(rows, minlength=n_rows) if n_rows else \
        np.zeros(0, np.int64)
    kmax = max(int(counts.max(initial=0)), min_k)
    ell_c = np.full((n_rows, kmax), -1, np.int32)
    ell_w = np.zeros((n_rows, kmax), np.float32)
    slot = np.zeros(n_rows, np.int64)
    for e in np.argsort(rows, kind="stable"):
        r = rows[e]
        ell_c[r, slot[r]] = cols[e]
        ell_w[r, slot[r]] = weights[e]
        slot[r] += 1
    return ell_c, ell_w


@pytest.mark.parametrize("n_rows,n_edges", [(1, 1), (7, 40), (64, 500),
                                            (13, 13), (5, 0)])
def test_build_ell_matches_loop(n_rows, n_edges):
    rng = np.random.RandomState(n_rows * 1000 + n_edges)
    rows = rng.randint(0, n_rows, n_edges)
    cols = rng.randint(0, 50, n_edges)
    wts = rng.randn(n_edges).astype(np.float32)
    got_c, got_w = build_ell(rows, cols, wts, n_rows)
    ref_c, ref_w = _ell_loop_reference(rows, cols, wts, n_rows)
    np.testing.assert_array_equal(got_c, ref_c)
    np.testing.assert_array_equal(got_w, ref_w)


def test_build_ell_degenerate():
    c, w = build_ell(np.zeros(0, int), np.zeros(0, int), np.zeros(0), 0)
    assert c.shape == (0, 1) and w.shape == (0, 1)
    c, w = build_ell(np.zeros(0, int), np.zeros(0, int), np.zeros(0), 3)
    assert c.shape == (3, 1) and (c == -1).all() and (w == 0).all()


def test_partition_ell_tables_match_spmv(graph_small):
    """The vectorized Partition.ell_tables drives spmv_ell to the same
    product as the numpy spmv (the satellite regression: no per-edge
    Python loop, same ELL layout)."""
    edges, n = graph_small
    parts = build_partitions(edges, n, 4)
    rng = np.random.RandomState(0)
    for p in parts:
        c, w = p.ell_tables()
        ref_c, ref_w = _ell_loop_reference(p.dst_pos, p.src_pos,
                                           p.inv_outdeg, len(p.out_idx))
        np.testing.assert_array_equal(c, ref_c)
        np.testing.assert_array_equal(np.asarray(w, np.float32), ref_w)
        x = rng.randn(len(p.in_idx))
        np.testing.assert_allclose(p.spmv_ell(x), p.spmv(x),
                                   rtol=1e-5, atol=1e-8)


@pytest.fixture(scope="module")
def graph_small():
    return powerlaw_graph(300, 2000, seed=1), 300


# ---------------------------------------------------------------------------
# ell_matvec paths
# ---------------------------------------------------------------------------

def test_ell_matvec_widths_and_kernel():
    import jax.numpy as jnp
    rng = np.random.RandomState(3)
    cols = rng.randint(-1, 20, (16, 5)).astype(np.int32)
    wts = rng.randn(16, 5).astype(np.float32)
    x1 = rng.randn(20).astype(np.float32)
    xw = rng.randn(20, 3).astype(np.float32)
    ref1 = np.zeros(16)
    refw = np.zeros((16, 3))
    for r in range(16):
        for k in range(5):
            if cols[r, k] >= 0:
                ref1[r] += wts[r, k] * x1[cols[r, k]]
                refw[r] += wts[r, k] * xw[cols[r, k]]
    got1 = np.asarray(ell_matvec(jnp.asarray(cols), jnp.asarray(wts),
                                 jnp.asarray(x1)))
    gotw = np.asarray(ell_matvec(jnp.asarray(cols), jnp.asarray(wts),
                                 jnp.asarray(xw)))
    gotk = np.asarray(ell_matvec(jnp.asarray(cols), jnp.asarray(wts),
                                 jnp.asarray(x1), use_kernel=True))
    np.testing.assert_allclose(got1, ref1, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gotw, refw, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gotk, ref1, rtol=1e-5, atol=1e-6)


def test_stack_ell_pads():
    t1 = (np.array([[1, 2]], np.int32), np.array([[1.0, 2.0]], np.float32))
    t2 = (np.full((3, 1), 0, np.int32), np.ones((3, 1), np.float32))
    cols, wts = stack_ell([t1, t2], 4)
    assert cols.shape == (2, 4, 2) and wts.shape == (2, 4, 2)
    assert cols[0, 0, 1] == 2 and cols[1, 2, 0] == 0
    assert (cols[0, 1:] == -1).all() and (cols[1, :, 1] == -1).all()


# ---------------------------------------------------------------------------
# engine on a single-device 1-node mesh: the amortization contract
# ---------------------------------------------------------------------------

def test_engine_one_dispatch_per_run(graph_small):
    """engine.run(k): exactly one jitted dispatch, the per-round body and
    the planned reduce traced exactly once (lax.scan, not an unrolled or
    per-iteration loop) — for any k; re-running the same k re-dispatches
    without re-tracing."""
    edges, n = graph_small
    parts = build_partitions(edges, n, 1)
    engine, extras, p0 = make_pagerank_engine(parts, n, degrees=())
    reduce_traces = []
    orig = engine.planned.reduce_on_device
    engine.planned.reduce_on_device = \
        lambda *a, **k: (reduce_traces.append(1), orig(*a, **k))[1]
    engine.run(7, p0, extras)
    assert engine.report == {"dispatches": 1, "rounds": 7, "step_traces": 1}
    assert len(reduce_traces) == 1
    engine.run(7, p0, extras)          # cached compile: no new trace
    assert engine.report == {"dispatches": 2, "rounds": 14, "step_traces": 1}
    assert len(reduce_traces) == 1
    engine.run(3, p0, extras)          # new k: one more trace, one dispatch
    assert engine.report == {"dispatches": 3, "rounds": 17, "step_traces": 2}
    assert len(reduce_traces) == 2
    rep = engine.sync_report()
    assert rep["host_roundtrips"] == 3
    assert rep["reduce_collectives_per_round"] == 2 * rep["butterfly_depth"]


def test_engine_one_dispatch_per_run_overlap(graph_small):
    """The amortization contract survives the double-buffered schedule:
    a ``run(k)`` on an ``overlap=True`` engine is still one dispatch with
    the rotated body traced once (the reduce *halves* each appear twice
    per build — prologue/epilogue plus the scanned body — but never per
    round), and re-running a cached k re-traces nothing.  k=1 falls back
    to the synchronous body."""
    from repro.graph.engine import GraphEngine
    edges, n = graph_small
    parts = build_partitions(edges, n, 1)
    base, extras, p0 = make_pagerank_engine(parts, n, degrees=())
    engine = GraphEngine(base.out_sets, base.in_sets, base.app,
                         degrees=(), overlap=True)
    down_traces, up_traces = [], []
    orig_down = engine.planned.reduce_down_on_device
    orig_up = engine.planned.reduce_up_on_device
    engine.planned.reduce_down_on_device = \
        lambda *a, **k: (down_traces.append(1), orig_down(*a, **k))[1]
    engine.planned.reduce_up_on_device = \
        lambda *a, **k: (up_traces.append(1), orig_up(*a, **k))[1]
    engine.run(7, p0, extras)
    assert engine.report == {"dispatches": 1, "rounds": 7, "step_traces": 1}
    assert len(down_traces) == 2 and len(up_traces) == 2
    engine.run(7, p0, extras)          # cached compile: no new trace
    assert engine.report == {"dispatches": 2, "rounds": 14, "step_traces": 1}
    assert len(down_traces) == 2 and len(up_traces) == 2
    engine.run(3, p0, extras)          # new k: one more build
    assert engine.report == {"dispatches": 3, "rounds": 17, "step_traces": 2}
    assert len(down_traces) == 4 and len(up_traces) == 4
    rep = engine.sync_report()
    assert rep["overlap"] is True
    assert rep["host_roundtrips"] == 3
    # k=1 has nothing to rotate: the synchronous fallback body runs
    # (reduce_on_device composes the same halves, once each)
    engine.run(1, p0, extras)
    assert engine.report == {"dispatches": 4, "rounds": 18, "step_traces": 3}
    assert len(down_traces) == 5 and len(up_traces) == 5


def test_engine_pagerank_single_node_matches_dense(graph_small):
    edges, n = graph_small
    ref = pagerank_dense_reference(edges, n, iters=8)
    got, stats = pagerank(edges, n, m=1, degrees=(), iters=8,
                          backend="device")
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-8)
    assert stats["engine"]["dispatches"] == 1
    assert stats["engine"]["rounds"] == 8
    gotk, _ = pagerank(edges, n, m=1, degrees=(), iters=8, backend="device",
                       use_kernel=True)
    np.testing.assert_allclose(gotk, ref, rtol=1e-4, atol=1e-8)


def test_engine_validation(graph_small):
    edges, n = graph_small
    parts = build_partitions(edges, n, 1)
    engine, extras, p0 = make_pagerank_engine(parts, n, degrees=())
    with pytest.raises(ValueError):
        engine.run(0, p0, extras)
    with pytest.raises(ValueError):
        engine.run(2, p0, extras, collect="everything")
    from repro.core import SparseAllreduce
    ar = SparseAllreduce(1, (), backend="sim")
    with pytest.raises(ValueError):
        ar.planned_parts()
    ar_dev = SparseAllreduce(1, (), backend="device")
    with pytest.raises(RuntimeError):
        ar_dev.planned_parts()
    with pytest.raises(RuntimeError):
        ar_dev.staging_metadata()


# ---------------------------------------------------------------------------
# multi-device parity (subprocess, 16 forced host devices)
# ---------------------------------------------------------------------------

PARITY_CODE = r"""
import numpy as np, jax
from repro.data.pipeline import powerlaw_graph
from repro.graph.hadi import hadi, hadi_bitstring_reference
from repro.graph.pagerank import (build_partitions, make_pagerank_engine,
                                  pagerank, pagerank_dense_reference)
from repro.graph.spectral import power_iteration, power_iteration_reference

DEVS = np.array(jax.devices())
def mesh_of(n):
    return jax.sharding.Mesh(DEVS[:n], ("nodes",))

edges = powerlaw_graph(500, 3000, seed=1)
n = 500

# PageRank: k-iteration device == sim oracle == dense reference (fp32 tol)
ref = pagerank_dense_reference(edges, n, iters=10)
for m, degs, use_kernel in [(8, (4, 2), False), (4, (2, 2), True)]:
    sim, _ = pagerank(edges, n, m=m, degrees=degs, iters=10)
    got, stats = pagerank(edges, n, m=m, degrees=degs, iters=10,
                          backend="device", use_kernel=use_kernel,
                          mesh=mesh_of(m))
    np.testing.assert_allclose(got, sim, rtol=1e-4, atol=1e-10)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-10)
    assert stats["engine"]["dispatches"] == 1, stats["engine"]
    assert stats["engine"]["rounds"] == 10
    assert stats["engine"]["step_traces"] == 1

# one-dispatch regression on a real multi-device mesh
parts = build_partitions(edges, n, 8)
engine, extras, p0 = make_pagerank_engine(parts, n, (2, 2, 2),
                                          mesh=mesh_of(8))
traces = []
orig = engine.planned.reduce_on_device
engine.planned.reduce_on_device = \
    lambda *a, **k: (traces.append(1), orig(*a, **k))[1]
engine.run(6, p0, extras)
assert engine.report == {"dispatches": 1, "rounds": 6, "step_traces": 1}
assert len(traces) == 1
print("PAGERANK_ENGINE_OK")

# HADI: device bitstrings bit-identical to the sim oracle + global OR ref
eff_s, curve_s, st_s = hadi(edges, n, m=4, degrees=(4,), max_hops=5,
                            trials=3, bits=16)
eff_d, curve_d, st_d = hadi(edges, n, m=4, degrees=(4,), max_hops=5,
                            trials=3, bits=16, backend="device",
                            mesh=mesh_of(4))
assert eff_s == eff_d and st_s["hops_run"] == st_d["hops_run"]
np.testing.assert_array_equal(curve_s, curve_d)
np.testing.assert_array_equal(st_s["b_final"], st_d["b_final"])
refb = hadi_bitstring_reference(edges, n, st_d["b0"].reshape(n, -1),
                                st_d["hops_run"])
np.testing.assert_array_equal(st_d["b_final"].reshape(n, -1), refb)
assert st_d["engine"]["dispatches"] == 1
print("HADI_ENGINE_OK")

# spectral: fused normalize (psum) per round, tolerance-bounded
lam_r, v_r = power_iteration_reference(edges, n, iters=20, seed=2)
lam_d, v_d, st = power_iteration(edges, n, m=4, degrees=(2, 2), iters=20,
                                 seed=2, backend="device", mesh=mesh_of(4))
assert abs(lam_d - lam_r) / lam_r < 1e-4, (lam_d, lam_r)
cos = abs(np.dot(v_d, v_r)) / (np.linalg.norm(v_d) * np.linalg.norm(v_r))
assert cos > 1 - 1e-6, cos
assert st["engine"]["dispatches"] == 1 and st["engine"]["rounds"] == 20
print("SPECTRAL_ENGINE_OK")
"""


@pytest.mark.slow
def test_engine_parity_device_vs_sim_16dev():
    """k-iteration PageRank/HADI/spectral on the device engine match the
    simulator oracle (HADI bit-identically) with exactly one dispatch and
    one body trace per run, on 4/8-node meshes in a 16-device
    subprocess."""
    out = _run(PARITY_CODE)
    assert "PAGERANK_ENGINE_OK" in out
    assert "HADI_ENGINE_OK" in out
    assert "SPECTRAL_ENGINE_OK" in out
