"""Multi-device shard_map allreduce correctness (subprocess: 8 CPU devices).

Per the brief, the main pytest process stays single-device; these tests
spawn one subprocess each with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys

import pytest

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src")
            + os.pathsep + os.environ.get("PYTHONPATH", ""))


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


UNION_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.allreduce import make_device_plan, run_union_allreduce
from repro.core.sparse_vec import HashPerm

rng = np.random.RandomState(1)
M, C, R = 8, 64, 4096
perm = HashPerm.make(7)
idx = np.full((M, C), 0xFFFFFFFF, np.uint32)
val = np.zeros((M, C), np.float32)
acc = {}
for n in range(M):
    nn = rng.randint(10, C // 2)
    oi = rng.choice(R, size=nn, replace=False).astype(np.uint32)
    ov = rng.randn(nn).astype(np.float32)
    h = perm.fwd_np(oi); order = np.argsort(h)
    idx[n, :nn] = h[order]; val[n, :nn] = ov[order]
    for j in range(nn):
        acc[int(h[j])] = acc.get(int(h[j]), 0.0) + float(ov[j])
want_idx = np.array(sorted(acc), np.uint32)
want_val = np.array([acc[int(k)] for k in want_idx])
mesh = jax.make_mesh((8,), ("d",))
for degs in [(4, 2), (2, 2, 2), (8,), (2, 4)]:
    plan = make_device_plan([("d", 8)], {"d": degs}, in_capacity=C,
                            out_capacity=M * C)
    oi, ov, ovf = run_union_allreduce(mesh, plan, jnp.asarray(idx),
                                      jnp.asarray(val))
    oi, ov = np.asarray(oi), np.asarray(ov)
    assert np.asarray(ovf).sum() == 0
    for n in range(M):
        m = oi[n] != 0xFFFFFFFF
        assert np.array_equal(oi[n][m], want_idx), degs
        np.testing.assert_allclose(ov[n][m], want_val, rtol=1e-5)
print("UNION_OK")
"""


PLANNED_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.allreduce import make_device_plan
from repro.core.planned import plan_sparse_allreduce
from repro.core.simulator import dense_oracle
from repro.core.sparse_vec import HashPerm

rng = np.random.RandomState(3)
M, R = 8, 3000
perm = HashPerm.make(11)
out_idx = [rng.randint(0, R, rng.randint(30, 120)).astype(np.uint32)
           for _ in range(M)]
out_val = [rng.randn(len(o)).astype(np.float32) for o in out_idx]
in_idx = [rng.choice(R, rng.randint(20, 90), replace=False).astype(np.uint32)
          for _ in range(M)]
mesh = jax.make_mesh((8,), ("d",))
oracle = dense_oracle(out_idx, out_val, in_idx, perm)
for degs in [(4, 2), (8,)]:
    dplan = make_device_plan([("d", 8)], {"d": degs}, 128, 1024)
    p = plan_sparse_allreduce(dplan, out_idx, in_idx, perm=perm)
    fn = p.make_reduce_fn(mesh)
    u = p.user_scatter.shape[1]
    vals = np.zeros((M, u), np.float32)
    for n in range(M):
        vals[n, :len(out_val[n])] = out_val[n]
    out = np.asarray(fn(jnp.asarray(vals)))
    for n in range(M):
        np.testing.assert_allclose(out[n, :len(in_idx[n])], oracle[n],
                                   rtol=1e-5, atol=1e-6)
    # reduce again with fresh values (config reused)
    vals2 = vals * 2.0
    out2 = np.asarray(fn(jnp.asarray(vals2)))
    for n in range(M):
        np.testing.assert_allclose(out2[n, :len(in_idx[n])],
                                   [2*x for x in oracle[n]], rtol=1e-5,
                                   atol=1e-6)
print("PLANNED_OK")
"""


DENSE_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from jax import lax
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.core.allreduce import (dense_allreduce_binary,
                                  dense_allreduce_hierarchical,
                                  make_device_plan)

mesh = jax.make_mesh((8,), ("d",))
x = np.random.RandomState(0).randn(8, 64).astype(np.float32)
want = x.sum(0)
plan = make_device_plan([("d", 8)], {"d": (4, 2)}, 8, 8)

def body(v):
    h = dense_allreduce_hierarchical(v[0], plan)
    b = dense_allreduce_binary(v[0], "d", 8)
    r = lax.psum(v[0], "d")
    return h[None], b[None], r[None]

fn = shard_map(body, mesh=mesh, in_specs=P("d"),
               out_specs=(P("d"), P("d"), P("d")), check_vma=False)
h, b, r = fn(jnp.asarray(x))
for got in (h, b, r):
    for n in range(8):
        np.testing.assert_allclose(np.asarray(got)[n], want, rtol=1e-5)
print("DENSE_OK")
"""


SYNC_MODES_CODE = r"""
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import transformer as T
from repro.optim.adamw import AdamW
from repro.train.step import make_train_step

cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                          tie_embeddings=False)
mesh = jax.make_mesh((4, 2), ("data", "model"))
params0 = T.init_params(cfg, tp=2, seed=0)
rng = np.random.RandomState(0)
B, S = 8, 32
batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32)}
results = {}
for sync in ("ring", "hier", "sparse"):
    step, _ = make_train_step(cfg, mesh, sync=sync, donate=False)
    p, o, m = step(params0, AdamW().init(params0), batch)
    results[sync] = (jax.tree.leaves(p), float(m["loss"]),
                     float(m["sync_overflow"]))
assert results["sparse"][2] == 0.0, "sparse sync overflowed"
for sync in ("hier", "sparse"):
    assert abs(results[sync][1] - results["ring"][1]) < 1e-5
    for a, b in zip(results[sync][0], results["ring"][0]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
print("SYNC_MODES_OK")
"""


MICROBATCH_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import transformer as T
from repro.optim.adamw import AdamW
from repro.train.step import make_train_step

cfg = get_config("qwen1.5-0.5b").reduced()
mesh = jax.make_mesh((2, 2), ("data", "model"))
params0 = T.init_params(cfg, tp=2, seed=0)
rng = np.random.RandomState(0)
B, S = 8, 32
batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32)}
outs = {}
for micro in (1, 4):
    step, _ = make_train_step(cfg, mesh, donate=False, microbatch=micro)
    p, o, m = step(params0, AdamW().init(params0), batch)
    outs[micro] = (jax.tree.leaves(p), float(m["loss"]))
assert abs(outs[1][1] - outs[4][1]) < 1e-4
for a, b in zip(outs[1][0], outs[4][0]):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4,
                               atol=3e-5)
print("MICROBATCH_OK")
"""


@pytest.mark.slow
def test_union_allreduce_8dev():
    assert "UNION_OK" in _run(UNION_CODE)


@pytest.mark.slow
def test_planned_allreduce_8dev():
    assert "PLANNED_OK" in _run(PLANNED_CODE)


@pytest.mark.slow
def test_dense_baselines_8dev():
    assert "DENSE_OK" in _run(DENSE_CODE)


@pytest.mark.slow
def test_grad_sync_modes_equivalent_8dev():
    """ring / hier / sparse sync produce the same update (the paper's
    primitive is a drop-in replacement for psum)."""
    assert "SYNC_MODES_OK" in _run(SYNC_MODES_CODE)


@pytest.mark.slow
def test_microbatch_accumulation_equivalent():
    assert "MICROBATCH_OK" in _run(MICROBATCH_CODE)


SERVE2D_CODE = r"""
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import transformer as T
from repro.train.step import make_decode_step, make_prefill_step

cfg = dataclasses.replace(get_config("command-r-plus-104b").reduced(),
                          fsdp=True)
mesh = jax.make_mesh((2, 2), ("data", "model"))
params = T.init_params(cfg, tp=2, seed=0)
rng = np.random.RandomState(0)
B, S, MAX = 4, 12, 16
prefill, _ = make_prefill_step(cfg, mesh, max_seq=MAX)
toks = rng.randint(0, cfg.vocab, (B, S)).astype(np.int32)
logits, cache = prefill(params, {"tokens": jnp.asarray(toks)})
tok = jnp.asarray(np.argmax(np.asarray(logits), -1), jnp.int32)
pos = jnp.full((B,), S, jnp.int32)
lg_g, _ = make_decode_step(cfg, mesh, serve2d=False)[0](params, tok, pos, cache)
lg_2, _ = make_decode_step(cfg, mesh, serve2d=True)[0](params, tok, pos, cache)
np.testing.assert_allclose(np.asarray(lg_g), np.asarray(lg_2),
                           rtol=2e-3, atol=2e-3)

# MoE + hybrid variants (moe_ffn_2d / mamba_decode_2d)
for arch in ("arctic-480b", "jamba-1.5-large-398b"):
    cfg2 = dataclasses.replace(get_config(arch).reduced(), fsdp=True)
    params2 = T.init_params(cfg2, tp=2, seed=0)
    pf2, _ = make_prefill_step(cfg2, mesh, max_seq=MAX)
    lg0, cache0 = pf2(params2, {"tokens": jnp.asarray(toks)})
    t0 = jnp.asarray(np.argmax(np.asarray(lg0), -1), jnp.int32)
    g0, _ = make_decode_step(cfg2, mesh, serve2d=False)[0](
        params2, t0, pos, cache0)
    s0, _ = make_decode_step(cfg2, mesh, serve2d=True)[0](
        params2, t0, pos, cache0)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(s0),
                               rtol=5e-3, atol=5e-3)

# seq-sharded (long-context) variant: batch replicated, cache over data
from repro.train.step import init_cache_global, mesh_ctx
mc = mesh_ctx(mesh)
cache2 = init_cache_global(cfg, mc, 2, 16)
cache2 = jax.tree.map(
    lambda x: jnp.asarray(np.random.RandomState(1).randn(*x.shape),
                          x.dtype) * 0.1, cache2)
tok2 = jnp.asarray(np.random.RandomState(2).randint(0, cfg.vocab, (2,)),
                   jnp.int32)
pos2 = jnp.full((2,), 5, jnp.int32)
g2, _ = make_decode_step(cfg, mesh, seq_sharded=True, seq_shards=2)[0](
    params, tok2, pos2, cache2)
s2, _ = make_decode_step(cfg, mesh, seq_sharded=True, seq_shards=2,
                         serve2d=True)[0](params, tok2, pos2, cache2)
np.testing.assert_allclose(np.asarray(g2), np.asarray(s2), rtol=3e-3,
                           atol=3e-3)
print("SERVE2D_OK")
"""


@pytest.mark.slow
def test_serve2d_matches_gather_decode():
    """2D weight-stationary decode (SPerf H4) == gather-mode decode."""
    assert "SERVE2D_OK" in _run(SERVE2D_CODE)


KERNEL_UNION_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.allreduce import make_device_plan, run_union_allreduce
from repro.core.sparse_vec import HashPerm

rng = np.random.RandomState(5)
M, C, R = 8, 48, 2048
perm = HashPerm.make(9)
idx = np.full((M, C), 0xFFFFFFFF, np.uint32)
val = np.zeros((M, C), np.float32)
for n in range(M):
    nn = rng.randint(8, C // 2)
    oi = rng.choice(R, nn, replace=False).astype(np.uint32)
    h = perm.fwd_np(oi); o = np.argsort(h)
    idx[n, :nn] = h[o]; val[n, :nn] = rng.randn(nn)
mesh = jax.make_mesh((8,), ("d",))
plan = make_device_plan([("d", 8)], {"d": (4, 2)}, C, M * C)
oi1, ov1, _ = run_union_allreduce(mesh, plan, jnp.asarray(idx),
                                  jnp.asarray(val), use_kernel=False)
oi2, ov2, _ = run_union_allreduce(mesh, plan, jnp.asarray(idx),
                                  jnp.asarray(val), use_kernel=True)
np.testing.assert_array_equal(np.asarray(oi1), np.asarray(oi2))
np.testing.assert_allclose(np.asarray(ov1), np.asarray(ov2), rtol=1e-5,
                           atol=1e-6)
print("KERNEL_UNION_OK")
"""


@pytest.mark.slow
def test_pallas_kernel_inside_union_allreduce():
    """MXU segment-compact kernel composes with the butterfly collectives."""
    assert "KERNEL_UNION_OK" in _run(KERNEL_UNION_CODE)


FUSED_UNION_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.api import SparseAllreduce
from repro.core.sparse_vec import HashPerm

rng = np.random.RandomState(2)
M, C, R = 8, 48, 2048
perm = HashPerm.make(9)
idx = np.full((M, C), 0xFFFFFFFF, np.uint32)
val = np.zeros((M, C), np.float32)
acc = {}
for n in range(M):
    nn = rng.randint(8, C // 2)
    oi = rng.choice(R, nn, replace=False).astype(np.uint32)
    ov = rng.randn(nn).astype(np.float32)
    h = perm.fwd_np(oi); o = np.argsort(h)
    idx[n, :nn] = h[o]; val[n, :nn] = ov[o]
    for j in range(nn):
        acc[int(h[j])] = acc.get(int(h[j]), 0.0) + float(ov[j])
want_idx = np.array(sorted(acc), np.uint32)
want_val = np.array([acc[int(k)] for k in want_idx])
mesh = jax.make_mesh((8,), ("d",))
outs = {}
for merge in ("sort", "fused", "banded"):
    ar = SparseAllreduce(8, (4, 2), backend="device", mesh=mesh, merge=merge)
    oi, ov, ovf = ar.union_reduce(jnp.asarray(idx), jnp.asarray(val),
                                  out_capacity=M * C)
    assert np.asarray(ovf).sum() == 0, merge
    oi, ov = np.asarray(oi), np.asarray(ov)
    for n in range(M):
        m = oi[n] != 0xFFFFFFFF
        assert np.array_equal(oi[n][m], want_idx), merge
        np.testing.assert_allclose(ov[n][m], want_val, rtol=1e-5)
    outs[merge] = (oi, ov)
for other in ("fused", "banded"):
    np.testing.assert_array_equal(outs["sort"][0], outs[other][0])
    np.testing.assert_array_equal(outs["sort"][1], outs[other][1])
print("FUSED_UNION_OK")
"""


@pytest.mark.slow
def test_fused_merge_union_allreduce_8dev():
    """merge='fused' / merge='banded' (Pallas rank-merge pipelines) ==
    merge='sort' through the full nested butterfly, selected via the
    SparseAllreduce knob."""
    assert "FUSED_UNION_OK" in _run(FUSED_UNION_CODE)
