"""Batched serving example: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python examples/serve_batched.py
    PYTHONPATH=src python examples/serve_batched.py --arch whisper-base
"""
import argparse

from repro.launch.serve import main as serve_main

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen1.5-0.5b")
ap.add_argument("--requests", type=int, default=4)
ap.add_argument("--gen", type=int, default=12)
args = ap.parse_args()

serve_main(["--arch", args.arch, "--reduced",
            "--requests", str(args.requests), "--gen", str(args.gen),
            "--prompt-len", "24"])
