"""Quickstart: the paper's two-call Sparse Allreduce API in 40 lines.

    PYTHONPATH=src python examples/quickstart.py

Eight logical nodes each contribute a sparse slice of a shared model and
ask for a (different) sparse subset of the sum back — the paper's §III-B
interface.  Also shows the topology tuner picking a heterogeneous degree
sequence (the paper's Fig 6 result) and the fault-tolerant mode.
"""
import numpy as np

from repro.core import SparseAllreduce, tune
from repro.core.simulator import dense_oracle
from repro.core.sparse_vec import HashPerm

M, R = 8, 10_000
rng = np.random.RandomState(0)

# every node contributes values at ~200 random indices, requests ~100 back
out_idx = [rng.choice(R, 200, replace=False).astype(np.uint32) for _ in range(M)]
out_val = [rng.randn(200) for _ in range(M)]
in_idx = [rng.choice(R, 100, replace=False).astype(np.uint32) for _ in range(M)]

# 1. let the tuner pick the degree sequence (paper Fig 6: hybrid wins)
plan = tune(M, n0=200, total_range=R)
print(f"tuned butterfly for M={M}: {plan}")

# 2. config once, reduce every iteration (paper §III-B)
ar = SparseAllreduce(M, plan.degrees)
stats = ar.config(out_idx, in_idx)
result = ar.reduce(out_val)
print(f"config {stats.config_time_s*1e3:.2f} ms (modeled EC2), "
      f"reduce {ar.stats.reduce_time_s*1e3:.2f} ms, "
      f"{ar.stats.total_bytes/1e6:.2f} MB on the wire")

# 3. verify against a dense oracle
oracle = dense_oracle(out_idx, out_val, in_idx, ar.perm)
for n in range(M):
    np.testing.assert_allclose(result[n], oracle[n], rtol=1e-9)
print("matches dense oracle on every node")

# 4. fault tolerance: r=2 replication, two dead machines (paper SV).
# The same knobs work on backend="device" (r*M mesh devices; see the
# README fault-tolerance section and benchmarks/bench_fault_tolerance.py).
ar2 = SparseAllreduce(M, plan.degrees, replication=2, dead={3, 9})
ar2.config(out_idx, in_idx)
result2 = ar2.reduce(out_val)
for n in range(M):
    np.testing.assert_allclose(result2[n], oracle[n], rtol=1e-9)
print("r=2 replication survives dead nodes {3, 9} with the exact same sums")
