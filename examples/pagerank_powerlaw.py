"""PageRank on a power-law graph through Sparse Allreduce (paper Fig 9).

    PYTHONPATH=src python examples/pagerank_powerlaw.py [--vertices 5000]

Builds a Chung-Lu power-law graph, random-edge-partitions it over 16
logical nodes (paper §II-B), runs 10 PageRank iterations with config called
once (static graph), and compares modeled communication time across
topologies — reproducing the round-robin vs binary vs hybrid trade-off.
"""
import argparse

import numpy as np

from repro.core.topology import ButterflyPlan, tune
from repro.data.pipeline import powerlaw_graph
from repro.graph.pagerank import pagerank, pagerank_dense_reference

ap = argparse.ArgumentParser()
ap.add_argument("--vertices", type=int, default=5000)
ap.add_argument("--edges", type=int, default=50000)
ap.add_argument("--nodes", type=int, default=16)
ap.add_argument("--iters", type=int, default=10)
args = ap.parse_args()

edges = powerlaw_graph(args.vertices, args.edges, seed=7)
print(f"graph: {args.vertices} vertices, {len(edges)} edges, "
      f"max in-degree {np.bincount(edges[:,1]).max()}")

ref = pagerank_dense_reference(edges, args.vertices, iters=args.iters)

for degrees in [(args.nodes,), (2,) * int(np.log2(args.nodes)), (4, 4),
                (8, 2)]:
    scores, stats = pagerank(edges, args.vertices, m=args.nodes,
                             degrees=degrees, iters=args.iters)
    err = np.max(np.abs(scores - ref))
    plan = ButterflyPlan(args.nodes, degrees)
    print(f"  {str(plan):10s} reduce {stats['reduce_time_s']*1e3:8.1f} ms "
          f"(modeled EC2)   max|err| {err:.2e}")

best = tune(args.nodes, n0=len(edges) / args.nodes, total_range=args.vertices)
print(f"tuner favours: {best}")
top = np.argsort(ref)[::-1][:5]
print("top-5 PageRank vertices:", top, np.round(ref[top], 5))
