"""End-to-end LM training driver over the framework's public API.

Default: a ~20M-param qwen-family model for 100 steps on CPU (minutes).
Scale up with --full / --steps; on a TPU mesh the same flags drive the
production path (the launcher picks the mesh from available devices).

    PYTHONPATH=src python examples/train_lm.py --steps 100
    PYTHONPATH=src python examples/train_lm.py --sync sparse --untied
"""
import argparse
import sys

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=100)
ap.add_argument("--sync", default="ring", choices=["ring", "hier", "sparse"])
ap.add_argument("--untied", action="store_true")
ap.add_argument("--full", action="store_true",
                help="full qwen1.5-0.5b config instead of the reduced one")
ap.add_argument("--arch", default="qwen1.5-0.5b")
args = ap.parse_args()

argv = ["--arch", args.arch, "--steps", str(args.steps), "--sync", args.sync,
        "--batch", "8", "--seq", "256", "--ckpt", "results/train_lm_ckpt"]
if not args.full:
    argv.append("--reduced")
if args.untied:
    argv.append("--untied")
final_loss = train_main(argv)
print(f"final loss: {final_loss:.4f}")
